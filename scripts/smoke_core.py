"""Quick end-to-end correctness smoke for the IS-LABEL core."""
import numpy as np

from repro.core import ISLabelIndex, IndexConfig
from repro.core import ref
from repro.graphs import generators as gen

rng = np.random.default_rng(0)
for name, (n, src, dst, w) in {
    "er": gen.er_graph(300, avg_deg=3.0, seed=1),
    "rmat": gen.rmat_graph(9, avg_deg=6.0, seed=2),
    "grid": gen.grid_graph(18, seed=3),
    "caveman": gen.caveman_graph(12, 8, seed=4),
}.items():
    cfg = IndexConfig(l_cap=256, label_chunk=512)
    idx = ISLabelIndex.build(n, src, dst, w, cfg)
    print(f"[{name}] {idx.stats.summary()} levels={idx.stats.level_sizes}")
    s = rng.integers(0, n, 200).astype(np.int32)
    t = rng.integers(0, n, 200).astype(np.int32)
    got = idx.query_host(s, t)
    oracle = ref.dijkstra_oracle(n, src, dst, w, s)
    want = oracle[np.arange(200), t]
    ok = np.allclose(got, want, equal_nan=False)
    bad = np.flatnonzero(~np.isclose(got, want))
    print(f"   query match: {ok}  (mismatches: {len(bad)})")
    if len(bad):
        for b in bad[:5]:
            print(f"   s={s[b]} t={t[b]} got={got[b]} want={want[b]}")
        raise SystemExit(1)
    # path reconstruction spot-check
    for qi in range(5):
        d, path = idx.shortest_path(int(s[qi]), int(t[qi]))
        if np.isfinite(d):
            assert path[0] == s[qi] and path[-1] == t[qi], (path, s[qi], t[qi])
            # verify path length == distance using edge dict
            ed = {}
            for a, b, ww in zip(src, dst, w):
                ed[(int(a), int(b))] = min(ed.get((int(a), int(b)), np.inf),
                                           float(ww))
            ln = sum(ed[(path[i], path[i + 1])] for i in range(len(path) - 1))
            assert abs(ln - d) < 1e-4, (ln, d, path)
    print("   paths ok")
print("ALL OK")
