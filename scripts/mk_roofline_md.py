"""Render experiments/dryrun/*.json into the EXPERIMENTS.md roofline table."""
import sys
sys.path.insert(0, "src")
sys.path.insert(0, ".")
from benchmarks.roofline_report import load, model_flops, _dev

recs = load()
ok = [r for r in recs if r.get("ok")]
fail = [r for r in recs if not r.get("ok")]
print(f"{len(ok)} ok, {len(fail)} failed")
for r in fail:
    print("FAIL:", r["arch"], r["shape"], r["mesh"])

rows = []
for r in ok:
    dev = r["devices"]
    mf = model_flops(r["arch"], r["shape"])
    hlo = r["flops_per_device"] * dev
    mem = r.get("mem") or {}
    temp = (mem.get("temp_size_in_bytes") or 0) / 1e9
    args = (mem.get("argument_size_in_bytes") or 0) / 1e9
    t_useful = mf / (197e12 * dev) if mf else None
    t_dom = max(r["t_compute_s"], r["t_memory_s"], r["t_collective_s"])
    rows.append((r["arch"], r["shape"], r["mesh"], r["t_compute_s"], r["t_memory_s"],
                 r["t_collective_s"], r["dominant"], (mf/hlo) if mf else None,
                 (t_useful/t_dom) if mf else None, args, temp))
rows.sort()
print("\n| arch | shape | mesh | t_comp (s) | t_mem (s) | t_coll (s) | dominant | useful/HLO | roofline frac | args GB/dev | temp GB/dev |")
print("|---|---|---|---|---|---|---|---|---|---|---|")
for a, s, m, tc, tm, tl, dom, ur, rf, ag, tp in rows:
    f = lambda x: ("%.3g" % x) if isinstance(x, float) else "—"
    print(f"| {a} | {s} | {m} | {f(tc)} | {f(tm)} | {f(tl)} | {dom} | {f(ur) if ur else '—'} | {f(rf) if rf else '—'} | {f(ag)} | {f(tp)} |")
