#!/usr/bin/env python
"""Bench-trajectory regression gate (docs/OBSERVABILITY.md).

Diffs fresh ``BENCH_*.json`` documents against the committed baseline
at the repo root and exits nonzero when any metric regressed beyond
tolerance — the CI ``bench-gate`` job:

  python scripts/obs_report.py --fresh bench-out \
      --timing-tolerance 1.5 --behavior-tolerance 0.05 \
      --fail-on behavior --report-out bench-out/regression-report.txt

Timing metrics (us_per_call rows, qps_compute, p99 latency) are
machine-dependent — CI passes a loose tolerance and, under
``--fail-on behavior``, timing drift beyond it only warns. Behavior
metrics (cache_hit_rate, batch_fill_ratio, per-lane request counts,
exactness/parity flags, fill ratios, relaxation round counts, overflow
counts) are deterministic given the same trace/preset, so the tight
default tolerance applies and always gates: drift there is a real
logic regression. Required-table coverage losses gate under either
policy. ``--report-out`` additionally writes the report to a file so
CI can upload it as an artifact.
"""
import argparse
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent
                       / "src"))

from repro.obs.regression import compare_dirs, format_report


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline", default=".",
                    help="directory with the committed BENCH_*.json "
                         "(default: repo root)")
    ap.add_argument("--fresh", required=True,
                    help="directory with the freshly generated "
                         "BENCH_*.json")
    ap.add_argument("--tables", default="",
                    help="comma-separated table names to REQUIRE (e.g. "
                         "serving,query); a required table missing from "
                         "the fresh run fails the gate. Empty: compare "
                         "whatever overlaps")
    ap.add_argument("--timing-tolerance", type=float, default=0.5,
                    help="relative tolerance for timing metrics")
    ap.add_argument("--behavior-tolerance", type=float, default=0.05,
                    help="relative tolerance for deterministic behavior "
                         "metrics")
    ap.add_argument("--fail-on", choices=["any", "behavior"],
                    default="any",
                    help="'any': every regression gates (legacy). "
                         "'behavior': only behavior/coverage regressions "
                         "gate; timing drift beyond tolerance warns")
    ap.add_argument("--report-out", default=None,
                    help="also write the report to this file (CI "
                         "artifact)")
    args = ap.parse_args()
    tables = [t for t in args.tables.split(",") if t] or None
    regs, compared, skipped = compare_dirs(
        args.baseline, args.fresh, tables=tables,
        timing_tolerance=args.timing_tolerance,
        behavior_tolerance=args.behavior_tolerance)
    report = format_report(regs, compared, skipped,
                           timing_tolerance=args.timing_tolerance,
                           behavior_tolerance=args.behavior_tolerance)
    if args.fail_on == "behavior":
        gating = [r for r in regs if r.kind != "timing"]
        warn = len(regs) - len(gating)
        if warn:
            report += (f"\nWARN: {warn} timing regression(s) above "
                       "tolerance — not gating under --fail-on behavior")
        if regs and not gating:
            report += "\nOK (gate): no behavior/coverage regressions"
    else:
        gating = regs
    print(report)
    if not compared and not regs:
        print("WARNING: no tables compared (no overlapping BENCH_*.json)")
    if args.report_out:
        out = pathlib.Path(args.report_out)
        out.parent.mkdir(parents=True, exist_ok=True)
        out.write_text(report + "\n")
        print(f"# report written to {out}")
    return 1 if gating else 0


if __name__ == "__main__":
    raise SystemExit(main())
